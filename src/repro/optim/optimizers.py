"""Optimizers (optax-free, zero extra deps): AdamW and Adafactor.

Adafactor matters at the 1T scale: factored second moments cut optimizer
state from 8 bytes/param to ~4 (bf16 first moment + rank-1 factors), which
is what lets kimi-k2 train on 512 chips (see EXPERIMENTS.md §Dry-run).

Each optimizer also exposes ``state_specs(param_specs)`` so the dry-run can
shard optimizer state exactly like parameters (ZeRO-style).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class Optimizer(NamedTuple):
    init: Callable          # params -> state
    update: Callable        # (grads, state, params, step) -> (new_params, state)
    state_specs: Callable   # param_specs -> state specs


def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * (step + 1) / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr: Callable | float, *, b1=0.9, b2=0.95, eps=1e-8,
          weight_decay=0.1) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        stepf = step.astype(jnp.float32) + 1.0
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1 ** stepf
        bc2 = 1.0 - b2 ** stepf

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        new_p = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"mu": mu, "nu": nu}

    def state_specs(pspecs, abstract_params=None):
        return {"mu": pspecs, "nu": pspecs}

    return Optimizer(init, update, state_specs)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; bf16 first moment)
# ---------------------------------------------------------------------------

def adafactor(lr: Callable | float, *, b1=0.9, decay=0.99, eps=1e-30,
              weight_decay=0.0, clip_rms=1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def vrow(p):
            return (jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p)
                    else jnp.zeros(p.shape, jnp.float32))

        def vcol(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if _factored(p) else jnp.zeros((1,), jnp.float32))

        return {"mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16),
                                   params),
                "vr": jax.tree.map(vrow, params),
                "vc": jax.tree.map(vcol, params)}

    def update(grads, state, params, step):
        lr_t = lr_fn(step)

        def upd(g, m, vr, vc, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = decay * vr + (1 - decay) * g2.mean(-1)
                vc = decay * vc + (1 - decay) * g2.mean(-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(-1)[..., None, None], eps))
                u = g * jax.lax.rsqrt(jnp.maximum(denom, eps))
            else:
                vr = decay * vr + (1 - decay) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(vr, eps))
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_rms)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * u
            u = m32 + weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr_t * u).astype(p.dtype),
                    m32.astype(jnp.bfloat16), vr, vc)

        out = jax.tree.map(upd, grads, state["mu"], state["vr"], state["vc"],
                           params)
        pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"mu": pick(1), "vr": pick(2), "vc": pick(3)}

    def state_specs(pspecs, abstract_params):
        """Needs param ranks to know which leaves are factored."""
        def norm(s, nd):
            t = tuple(s) + (None,) * (nd - len(tuple(s)))
            return t

        def row(s, p):
            if p.ndim >= 2:
                return P(*norm(s, p.ndim)[:-1])
            return P(*norm(s, p.ndim))

        def col(s, p):
            if p.ndim >= 2:
                t = norm(s, p.ndim)
                return P(*(t[:-2] + t[-1:]))
            return P(None)

        is_spec = lambda x: isinstance(x, P)
        vr = jax.tree.map(row, pspecs, abstract_params, is_leaf=is_spec)
        vc = jax.tree.map(col, pspecs, abstract_params, is_leaf=is_spec)
        return {"mu": pspecs, "vr": vr, "vc": vc}

    return Optimizer(init, update, state_specs)
