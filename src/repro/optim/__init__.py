from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adafactor,
    adamw,
    clip_by_global_norm,
    warmup_cosine,
)
