"""GEEK — a generic distributed clustering framework, reproduced in JAX.

One estimator, any data kind, any execution mode (DESIGN.md §11)::

    from repro import GEEK, DenseData, GeekConfig

    est = GEEK(GeekConfig(k_max=256))
    model = est.fit(DenseData(x), key)              # in-core
    model = est.fit(DenseData(x), key, chunk=8192)  # streaming
    model = est.fit(DenseData(x), key, mesh=mesh)   # sharded
    labels, dists = est.predict(DenseData(new_x))   # serving

Plus the async serving tier (DESIGN.md §13)::

    from repro.serve import ClusterServer

This top-level namespace is the supported public API, locked by
``tests/test_api_surface.py``. Everything else (``repro.core.*``
internals, ``repro.kernels``, the LM training stack) is implementation
detail and may change without deprecation.

The namespace resolves LAZILY (PEP 562): importing ``repro`` — or a
light submodule like ``repro.utils.platform`` — must not initialize
the JAX backend, because platform configuration (``set_platform``, XLA
flags) only takes effect before the first backend use. The heavy
imports happen on first attribute access.
"""
import importlib

#: supported public symbol -> defining module (resolved on first access)
_LAZY = {
    "DenseData": "repro.core.api",
    "GEEK": "repro.core.api",
    "GeekConfig": "repro.core.geek",
    "GeekModel": "repro.core.model",
    "GeekResult": "repro.core.geek",
    "HeteroData": "repro.core.api",
    "KMeansPPSeeder": "repro.core.api",
    "KernelAssigner": "repro.core.api",
    "LSHBucketer": "repro.core.api",
    "SILKSeeder": "repro.core.api",
    "ScalableKMeansPPSeeder": "repro.core.api",
    "SparseData": "repro.core.api",
    "predict": "repro.core.model",
    "restore_model": "repro.checkpoint.manager",
    "save_model": "repro.checkpoint.manager",
}

#: the supported public surface (sorted; locked by tests/test_api_surface.py)
__all__ = sorted([*_LAZY, "serve"])


def __getattr__(name: str):
    """Resolve a public symbol (or the ``serve`` subpackage) on demand."""
    if name == "serve":
        mod = importlib.import_module("repro.serve")
        globals()[name] = mod
        return mod
    target = _LAZY.get(name)
    if target is None:
        raise AttributeError(f"module 'repro' has no attribute {name!r}")
    obj = getattr(importlib.import_module(target), name)
    globals()[name] = obj          # cache: next access skips __getattr__
    return obj


def __dir__():
    """Advertise the lazy public surface alongside real globals."""
    return sorted(set(globals()) | set(__all__))
