"""GEEK — a generic distributed clustering framework, reproduced in JAX.

One estimator, any data kind, any execution mode (DESIGN.md §11)::

    from repro import GEEK, DenseData, GeekConfig

    est = GEEK(GeekConfig(k_max=256))
    model = est.fit(DenseData(x), key)              # in-core
    model = est.fit(DenseData(x), key, chunk=8192)  # streaming
    model = est.fit(DenseData(x), key, mesh=mesh)   # sharded
    labels, dists = est.predict(DenseData(new_x))   # serving

This top-level namespace is the supported public API, locked by
``tests/test_api_surface.py``. Everything else (``repro.core.*``
internals, ``repro.kernels``, the LM training stack) is
implementation detail and may change without deprecation.
"""
from repro.checkpoint.manager import restore_model, save_model  # noqa: F401
from repro.core.api import (  # noqa: F401
    GEEK,
    DenseData,
    HeteroData,
    KernelAssigner,
    KMeansPPSeeder,
    LSHBucketer,
    ScalableKMeansPPSeeder,
    SILKSeeder,
    SparseData,
)
from repro.core.geek import GeekConfig, GeekResult  # noqa: F401
from repro.core.model import GeekModel, predict  # noqa: F401

#: the supported public surface (sorted; locked by tests/test_api_surface.py)
__all__ = [
    "DenseData",
    "GEEK",
    "GeekConfig",
    "GeekModel",
    "GeekResult",
    "HeteroData",
    "KMeansPPSeeder",
    "KernelAssigner",
    "LSHBucketer",
    "SILKSeeder",
    "ScalableKMeansPPSeeder",
    "SparseData",
    "predict",
    "restore_model",
    "save_model",
]
